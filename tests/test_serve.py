"""Serving engine: continuous batching must be *transparent* — every
request's greedy completion equals its single-request reference,
regardless of what else shares the batch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import reduced_config
from repro.models import lm
from repro.serve import Engine, EngineConfig


def _cfg(arch):
    # fp32 to make greedy argmax deterministic across batching layouts
    return dataclasses.replace(reduced_config(get_config(arch)), dtype="float32")


def _reference_greedy(params, cfg, prompt, n_new, max_len=64):
    """Single-request prefill + sequential decode (no batching)."""
    toks = jnp.asarray(np.array(prompt, np.int32)[None])
    logits, cache = lm.forward_prefill(params, cfg, toks, q_chunk=8)
    cache = lm.grow_cache(cfg, cache, max_len, len(prompt))
    out = [int(jnp.argmax(logits[0, : cfg.vocab_size]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        logits, cache = lm.decode_step(
            params, cfg, jnp.asarray([out[-1]], jnp.int32), jnp.int32(pos), cache
        )
        out.append(int(jnp.argmax(logits[0, : cfg.vocab_size])))
        pos += 1
    return out


@pytest.mark.parametrize("arch", ["qwen2-7b", "jamba-v0.1-52b"])
def test_continuous_batching_matches_reference(arch):
    cfg = _cfg(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    prompts = [
        list(rng.integers(0, cfg.vocab_size, size=n)) for n in (5, 8, 3, 11, 6)
    ]
    n_new = 6

    refs = [_reference_greedy(params, cfg, p, n_new) for p in prompts]

    eng = Engine(
        params, cfg,
        EngineConfig(max_slots=2, max_len=64, max_new_tokens=n_new,
                     prefill_buckets=(8, 16)),
    )
    rids = [eng.add_request(p) for p in prompts]
    done = eng.run()
    assert len(done) == len(prompts)
    by_rid = {r.rid: r.out for r in done}
    for rid, ref in zip(rids, refs):
        assert by_rid[rid] == ref, (
            f"{arch} request {rid}: engine {by_rid[rid]} != reference {ref}"
        )


def test_slots_are_recycled():
    cfg = _cfg("qwen2-7b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(
        params, cfg,
        EngineConfig(max_slots=2, max_len=64, max_new_tokens=3,
                     prefill_buckets=(8,)),
    )
    rng = np.random.default_rng(2)
    for _ in range(5):
        eng.add_request(list(rng.integers(0, cfg.vocab_size, size=4)))
    done = eng.run()
    assert len(done) == 5
    # never more slots in flight than the pool
    assert eng.free == sorted(eng.free) or len(eng.free) == 2


def test_eos_frees_slot_early():
    cfg = _cfg("qwen2-7b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prompt = list(rng.integers(0, cfg.vocab_size, size=4))
    ref = _reference_greedy(params, cfg, prompt, 8)
    eos = ref[2]  # force an early stop at the 3rd generated token
    eng = Engine(
        params, cfg,
        EngineConfig(max_slots=1, max_len=64, max_new_tokens=8, eos_id=eos,
                     prefill_buckets=(8,)),
    )
    eng.add_request(prompt)
    done = eng.run()
    assert done[0].out == ref[:3]
