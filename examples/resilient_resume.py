"""Resilient resume quickstart: survive a mid-run kill, continue on a
different rank count, end bitwise-identical (DESIGN.md §11).

A long stencil run wrapped in ``repro.resilience.ResilientLoop``
snapshots its global state every ``checkpoint_every`` epochs.  When the
process dies — here deterministically, via an injected ``FaultPlan`` —
``resume()`` picks up from the last committed snapshot, optionally onto
a *different* mesh factorization, and the final state is bitwise-equal
to the run that was never interrupted.

    PYTHONPATH=src python examples/resilient_resume.py
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/resilient_resume.py --ranks 4
"""
import argparse
import tempfile

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ranks", type=int, default=1,
                    help="ranks for the first (killed) run; the resume "
                         "uses half of them when >1")
    ap.add_argument("--size", type=int, default=128)
    ap.add_argument("--steps", type=int, default=64)
    args = ap.parse_args()

    import repro
    from repro.frontends.devito_like import Eq, Grid, Operator, TimeFunction
    from repro.resilience import FaultPlan, ResilientLoop, SimulatedFault, resume

    # -- the simulation: 2-D heat, depth-4 epochs --------------------------
    grid = Grid(shape=(args.size, args.size), extent=(1.0, 1.0))
    u = TimeFunction(name="u", grid=grid, space_order=2)
    dt = 0.8 * grid.spacing[0] ** 2 / (4 * 0.5)
    prog = Operator(Eq(u.dt, 0.5 * u.laplace), dt=dt, boundary="zero").program

    k = 4
    big = repro.Target.auto(ranks=args.ranks, exchange_every=k)
    u0 = np.zeros(grid.shape, np.float32)
    c = args.size // 2
    u0[c - 8 : c + 8, c - 8 : c + 8] = 1.0

    # uninterrupted reference (plain time_loop — the bitwise oracle)
    want = repro.compile(prog, big).time_loop((u0,), args.steps)
    want = want if isinstance(want, tuple) else (want,)

    # -- run with checkpointing; die mid-run deterministically -------------
    ckpt_dir = tempfile.mkdtemp(prefix="repro-resume-")
    kill = (args.steps // k) // 2
    loop = ResilientLoop(
        prog, big, (u0,), args.steps,
        directory=ckpt_dir, checkpoint_every=1, keep_last=3,
        fault_plan=FaultPlan(kill_at_epoch=kill),  # stands in for preemption
    )
    try:
        loop.run()
    except SimulatedFault as e:
        print(f"killed: {e}")
    print(f"committed snapshots: {loop.checkpointer.available_steps()} "
          f"(stats {loop.checkpointer.stats.as_dict()})")

    # -- resume: same program, possibly a different mesh -------------------
    # the snapshot holds GLOBAL state; resume() reshards it for whatever
    # target you hand it — halve the rank count when we have ranks to halve
    new_ranks = max(1, args.ranks // 2)
    small = repro.Target.auto(ranks=new_ranks, exchange_every=k)
    resumed = resume(prog, ckpt_dir, small)
    print(f"resumed at step {resumed.step_count}/{args.steps} "
          f"on {new_ranks} rank(s)")
    got = resumed.run()

    ok = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(got, want)
    )
    print(f"final state bitwise-equal to the uninterrupted run: {ok}")
    assert ok


if __name__ == "__main__":
    main()
