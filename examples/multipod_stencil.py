"""Multi-pod stencil dry-run: the paper's strong-scaling configuration on
the production mesh — 512 virtual devices, 2 pods × (16×16).

Lowers a 3-D so8 acoustic-wave stencil decomposed 8×8×8 over 512 ranks,
compiles it (proving the halo-exchange collectives schedule), and prints
the memory/cost/collective analysis — the stencil-side §Dry-run.

    PYTHONPATH=src python examples/multipod_stencil.py
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import re  # noqa: E402

import numpy as np  # noqa: E402


def main() -> None:
    import jax
    from jax.sharding import Mesh

    from repro import api
    from repro.core.passes.decompose import SlicingStrategy
    from repro.frontends.devito_like import Eq, Grid, Operator, TimeFunction

    assert len(jax.devices()) == 512, len(jax.devices())
    mesh = Mesh(
        np.array(jax.devices()).reshape(8, 8, 8), ("x", "y", "z")
    )
    strategy = SlicingStrategy((8, 8, 8), ("x", "y", "z"), (0, 1, 2))

    shape = (512, 512, 512)
    g = Grid(shape=shape, extent=(1.0,) * 3)
    u = TimeFunction(name="u", grid=g, space_order=8, time_order=2)
    op = Operator(Eq(u.dt2, 1.0 * u.laplace), dt=1e-7, boundary="zero")

    target = api.Target(mesh=mesh, strategy=strategy, overlap=True)
    artifact = api.compile(op.program, target)
    lowered = artifact.lower()
    compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax: one dict per program
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    n_permute = len(re.findall(r"collective-permute", hlo))
    print(f"mesh: 8x8x8 = {mesh.size} devices; grid {shape} so8 wave")
    print(f"compile OK; per-device args "
          f"{mem.argument_size_in_bytes/2**20:.1f} MiB, "
          f"temps {mem.temp_size_in_bytes/2**20:.1f} MiB")
    print(f"per-device flops {cost.get('flops', 0):.3e}, "
          f"bytes {cost.get('bytes accessed', 0):.3e}")
    print(f"collective-permute ops in HLO: {n_permute} "
          "(halo exchanges, 3 axes x 2 dirs x radius batches)")
    # the canonical comm-level IR: overlap is visible as starts → interior
    # apply → wait → frame applies (artifact.local_ir)
    local = artifact.local_ir
    from repro.core.dialects import comm

    print(f"pipeline: {artifact.pipeline_report.spec}")
    print("comm IR : " + " -> ".join(_rle(o.name for o in local.body.ops)))
    starts = [o for o in local.body.ops
              if isinstance(o, comm.ExchangeStartOp)]
    halo_bytes = sum(int(np.prod(s.size)) for s in starts) * 4
    print(f"comm model: {len(starts)} exchange_start(s), "
          f"{halo_bytes/2**20:.2f} MiB halo/rank/step "
          f"-> {halo_bytes/50e9*1e6:.0f} µs on 50 GB/s ICI")


def _rle(names):
    """['a','a','b'] → ['a x2', 'b'] — compact op-sequence printing."""
    out: list = []
    for n in names:
        short = n.split(".", 1)[-1]
        if out and out[-1][0] == short:
            out[-1][1] += 1
        else:
            out.append([short, 1])
    return [f"{n} x{c}" if c > 1 else n for n, c in out]


if __name__ == "__main__":
    main()
