"""Serving example: continuous-batching engine over a reduced model.

A stream of requests with different prompt lengths and arrival times
shares a fixed slot pool; finished slots are recycled immediately.

    PYTHONPATH=src python examples/serve_batch.py --arch qwen2-7b
    PYTHONPATH=src python examples/serve_batch.py --arch jamba-v0.1-52b
"""
import argparse
import dataclasses
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.configs.base import reduced_config
    from repro.models import lm
    from repro.serve import Engine, EngineConfig

    cfg = dataclasses.replace(
        reduced_config(get_config(args.arch)), dtype="float32"
    )
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(
        params, cfg,
        EngineConfig(
            max_slots=args.slots,
            max_len=128,
            max_new_tokens=args.new_tokens,
            prefill_buckets=(8, 16, 32),
        ),
    )

    rng = np.random.default_rng(0)
    lengths = rng.integers(3, 16, size=args.requests)
    t0 = time.perf_counter()
    for n in lengths:
        eng.add_request(list(rng.integers(0, cfg.vocab_size, size=int(n))))

    rounds = 0
    while eng.queue or eng.active:
        eng.step()
        rounds += 1
        if rounds % 5 == 0:
            print(f"round {rounds:3d}: active={len(eng.active)} "
                  f"queued={len(eng.queue)} done={len(eng.finished)} "
                  f"util={eng.utilization:.0%}")
    dt = time.perf_counter() - t0

    total_new = sum(len(r.out) for r in eng.finished)
    print(f"\n{len(eng.finished)} requests, {total_new} tokens "
          f"in {dt:.1f}s ({total_new/dt:.1f} tok/s incl. compile)")
    for r in sorted(eng.finished, key=lambda r: r.rid)[:4]:
        print(f"  req{r.rid}: prompt[{len(r.prompt)}] -> {r.out}")


if __name__ == "__main__":
    main()
