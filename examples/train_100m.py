"""End-to-end training driver: ~100M-parameter qwen2-family model,
synthetic tokens, full production loop (AdamW + schedule, remat,
checkpoint/restart, NaN guard, straggler watchdog).

    PYTHONPATH=src python examples/train_100m.py --steps 300

Re-running the same command resumes from the latest checkpoint —
kill it mid-run to see restart work.  ``--arch`` selects any of the 10
assigned architectures (reduced to ~100M scale automatically).
"""
import argparse
import dataclasses
import os


def build_100m(arch: str):
    from repro.configs import get_config
    from repro.configs.base import reduced_config

    base = get_config(arch)
    # ~100M-scale instantiation of the same family
    cfg = reduced_config(
        base,
        d_model=512,
        n_heads=8,
        n_kv_heads=max(2, min(base.n_kv_heads, 4)),
        head_dim=64,
        d_ff=1536 if base.d_ff > 0 else 0,
        vocab_size=32_000,
        n_layers=len(base.block_pattern) * max(1, 8 // len(base.block_pattern)),
    )
    return cfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    args = ap.parse_args()

    import jax

    from repro.data.pipeline import DataConfig
    from repro.train import optimizer as opt_mod
    from repro.train.train_step import (
        TrainOptions,
        init_train_state,
        make_train_step,
    )
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = build_100m(args.arch)
    n_params = cfg.param_count()
    print(f"arch={cfg.name} ({args.arch} family) params≈{n_params/1e6:.0f}M")

    step_fn = jax.jit(
        make_train_step(
            cfg,
            opt_mod.OptimizerConfig(peak_lr=3e-4, warmup_steps=20,
                                    decay_steps=args.steps),
            TrainOptions(q_chunk=min(256, args.seq)),
        ),
        donate_argnums=(0,),
    )
    trainer = Trainer(
        train_step=step_fn,
        init_state=lambda: init_train_state(jax.random.PRNGKey(0), cfg),
        data_cfg=DataConfig(
            seq_len=args.seq,
            global_batch=args.batch,
            vocab_size=cfg.vocab_size,
            modality_tokens=cfg.num_modality_tokens,
            modality_dim=cfg.modality_dim,
            modality_is_frames=cfg.modality == "audio",
        ),
        cfg=TrainerConfig(
            total_steps=args.steps,
            checkpoint_every=100,
            checkpoint_dir=args.ckpt_dir,
            log_every=10,
        ),
    )
    trainer.install_signal_handler()
    if trainer.start_step:
        print(f"resumed from checkpoint at step {trainer.start_step}")
    result = trainer.run()

    losses = [m["loss"] for m in result["metrics"] if "loss" in m]
    print(f"finished at step {result['final_step']}")
    print("loss trajectory:", " ".join(f"{l:.3f}" for l in losses))
    if len(losses) >= 2:
        assert losses[-1] < losses[0], "loss did not decrease"
        print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}  ✓")


if __name__ == "__main__":
    main()
