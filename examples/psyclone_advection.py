"""PSyclone-path example: stencils *recognized* from loop-style code
(the paper's Fortran-frontend story), then fused and decomposed by the
shared stack.

    PYTHONPATH=src python examples/psyclone_advection.py
"""
import numpy as np


# Loop-style kernels, as a scientist would write them (paper §5.2: the
# PSyclone backend identifies stencils from Fortran loops; here from
# Python loop bodies with i/j/k index conventions).


def pw_advection(u, v, w, su, sv, sw):
    su[i, j, k] = 0.5 * (
        u[i, j, k] * (v[i, j, k] + v[i + 1, j, k])
        - u[i - 1, j, k] * (v[i - 1, j, k] + v[i, j, k])
    )
    sv[i, j, k] = 0.5 * (
        v[i, j, k] * (w[i, j, k] + w[i, j + 1, k])
        - v[i, j - 1, k] * (w[i, j - 1, k] + w[i, j, k])
    )
    sw[i, j, k] = 0.5 * (
        w[i, j, k] * (u[i, j, k] + u[i, j, k + 1])
        - w[i, j, k - 1] * (u[i, j, k - 1] + u[i, j, k])
    )


def main() -> None:
    import jax.numpy as jnp

    from repro import api
    from repro.core.dialects import stencil
    from repro.core.passes import cse_apply_bodies, dce, fuse_applies
    from repro.frontends.psyclone_like import build_stencil_func

    shape = (64, 64, 32)
    func = build_stencil_func(pw_advection, shape)
    n_raw = sum(1 for op in func.body.ops if isinstance(op, stencil.ApplyOp))

    fuse_applies(func)
    cse_apply_bodies(func)
    dce(func)
    n_fused = sum(1 for op in func.body.ops if isinstance(op, stencil.ApplyOp))
    print(f"recognized {n_raw} stencil computations -> fused into {n_fused} "
          f"region(s)   (paper fig. 10: PW advection 3 -> 1)")

    prog = api.Program(func, boundary="periodic")
    print("\n--- fused stencil IR (what the fingerprint hashes) ---")
    print("\n".join(prog.ir_text().splitlines()[:20]) + "\n  ...")

    step = api.compile(prog, api.Target())
    rng = np.random.default_rng(0)
    args = [jnp.asarray(rng.standard_normal(shape), jnp.float32)
            for _ in prog.field_args]
    outs = step(*args)
    print(f"\nran fused kernel: {len(outs)} output fields, "
          f"all finite: {all(bool(jnp.isfinite(o).all()) for o in outs)}")


if __name__ == "__main__":
    main()
