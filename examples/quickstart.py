"""Quickstart: the paper's listing-5 experience on the JAX/TPU stack.

Model 2-D heat diffusion symbolically (Devito-like DSL), compile through
the shared stencil stack, and run it — single device here; pass
``--ranks N`` to decompose over N virtual devices with automatic dmp
halo exchanges (set XLA_FLAGS=--xla_force_host_platform_device_count=N
before running for N>1).

    PYTHONPATH=src python examples/quickstart.py
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py --ranks 8
"""
import argparse

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ranks", type=int, default=1)
    ap.add_argument("--size", type=int, default=256)
    ap.add_argument("--steps", type=int, default=100)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.core.passes.decompose import make_strategy_1d
    from repro.frontends.devito_like import Eq, Grid, Operator, TimeFunction

    # -- model the problem (paper listing 5) ------------------------------
    grid = Grid(shape=(args.size, args.size), extent=(1.0, 1.0))
    u = TimeFunction(name="u", grid=grid, space_order=2)
    eqn = Eq(u.dt, 0.5 * u.laplace)
    # explicit-Euler stability: dt <= h²/(4·alpha); run at 80% of it
    dt = 0.8 * grid.spacing[0] ** 2 / (4 * 0.5)
    op = Operator(eqn, dt=dt, boundary="zero")

    # -- initial condition: hot square in the center ----------------------
    u0 = np.zeros(grid.shape, np.float32)
    c = args.size // 2
    u0[c - 8 : c + 8, c - 8 : c + 8] = 1.0

    mesh = strategy = None
    if args.ranks > 1:
        assert len(jax.devices()) >= args.ranks, (
            f"need {args.ranks} devices; set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={args.ranks}"
        )
        mesh = Mesh(np.array(jax.devices()[: args.ranks]), ("x",))
        strategy = make_strategy_1d(args.ranks)
        print(f"decomposed over {args.ranks} ranks (1-D slabs + halo swaps)")

    (uT,) = op.apply([jnp.asarray(u0)], timesteps=args.steps,
                     mesh=mesh, strategy=strategy)
    uT = np.asarray(uT)

    print(f"steps={args.steps}  total heat: {u0.sum():.3f} -> {uT.sum():.3f}")
    print(f"peak: {u0.max():.3f} -> {uT.max():.3f} (diffused)")
    assert np.isfinite(uT).all()
    # crude ASCII rendering of the diffused blob
    ds = uT[:: args.size // 32, :: args.size // 32]
    chars = " .:-=+*#%@"
    for row in ds:
        print("".join(chars[int(min(v, 0.999) * 10)] for v in row))


if __name__ == "__main__":
    main()
