"""Quickstart: the paper's listing-5 experience on the JAX/TPU stack,
through the one compile surface — ``Program`` / ``Target`` / ``compile``.

1. model 2-D heat diffusion symbolically (Devito-like DSL) — the
   frontend produces a ``repro.api.Program`` (frontend-neutral IR);
2. describe *where and how* to run with a ``repro.api.Target`` (device
   mesh + decomposition strategy + backend + pipeline knobs);
3. ``repro.api.compile(program, target)`` returns a ``CompiledStencil``
   — a reusable artifact cached process-wide on (program fingerprint,
   target fingerprint), so compiling the same program twice is free.

    PYTHONPATH=src python examples/quickstart.py
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py --ranks 8
"""
import argparse

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ranks", type=int, default=1)
    ap.add_argument("--size", type=int, default=256)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--tune", action="store_true",
                    help="let repro.tune pick the Target (cost-model "
                         "search, persisted in ~/.cache/repro-tune)")
    args = ap.parse_args()

    import jax.numpy as jnp

    import repro
    from repro.frontends.devito_like import Eq, Grid, Operator, TimeFunction

    # -- 1. model the problem (paper listing 5) → Program ------------------
    grid = Grid(shape=(args.size, args.size), extent=(1.0, 1.0))
    u = TimeFunction(name="u", grid=grid, space_order=2)
    eqn = Eq(u.dt, 0.5 * u.laplace)
    # explicit-Euler stability: dt <= h²/(4·alpha); run at 80% of it
    dt = 0.8 * grid.spacing[0] ** 2 / (4 * 0.5)
    op = Operator(eqn, dt=dt, boundary="zero")
    prog = op.program
    print(f"program: {prog.name} fields={list(prog.field_names)} "
          f"fingerprint={prog.fingerprint}")

    # -- 2. describe the target -------------------------------------------
    # Target.auto() discovers devices (1-D decomposition over all of them);
    # an explicit Target(mesh=..., strategy=...) pins the layout; and
    # Target.tuned(prog) searches the whole space (mesh factorization,
    # overlap, exchange_every, backend, tile) with the roofline model —
    # the winner persists on disk, so the search runs once per machine:
    #
    #     target = repro.Target.tuned(prog)               # measured search
    #     target = repro.Target.tuned(prog, measure=False)  # cost model only
    #     step = repro.api.compile(prog, tune=True)         # tune + compile
    if args.tune:
        target = repro.Target.tuned(
            prog, ranks=args.ranks, measure=False
        )
        print(f"tuned target: backend={target.backend} "
              f"exchange_every={target.exchange_every} "
              f"overlap={target.overlap} distributed={target.distributed}")
    else:
        target = repro.Target.auto(ranks=args.ranks)
    if target.distributed:
        print(f"decomposed over {args.ranks} ranks (1-D slabs + halo swaps)")

    # -- 3. compile → CompiledStencil --------------------------------------
    step = repro.compile(prog, target)
    print(step.pipeline_report)

    # a second compile of the same program+target is a cache hit: the
    # pass pipeline does not re-run and the artifact is the same object
    again = repro.compile(op.program, target)
    stats = repro.cache_stats()
    print(f"recompile: cached={again is step} "
          f"(cache hits={stats.hits} misses={stats.misses})")

    # -- initial condition: hot square in the center ----------------------
    u0 = np.zeros(grid.shape, np.float32)
    c = args.size // 2
    u0[c - 8 : c + 8, c - 8 : c + 8] = 1.0

    # a depth-k tuned artifact advances whole epochs: round the step
    # count up to a multiple of k
    k = target.exchange_every
    if args.steps % k:
        args.steps += k - args.steps % k
    (uT,) = step.time_loop([jnp.asarray(u0)], args.steps)
    uT = np.asarray(uT)

    print(f"steps={args.steps}  total heat: {u0.sum():.3f} -> {uT.sum():.3f}")
    print(f"peak: {u0.max():.3f} -> {uT.max():.3f} (diffused)")
    assert np.isfinite(uT).all()

    # -- observability: trace one epoch + drift check (DESIGN.md §12) ------
    # obs.enable() switches time_loop to a per-epoch traced path (bitwise
    # equal, slower) so compile/dispatch/comm/compute spans land on one
    # timeline; write_chrome exports it for Perfetto, and drift_report
    # compares the measured epoch against the roofline model.
    from repro import obs

    obs.enable()
    obs.clear()
    step.time_loop([jnp.asarray(u0)], 2 * k)
    rep = obs.drift_report(terms=step.cost(), exchange_every=k)
    trace_path = obs.write_chrome("results/quickstart_trace.json")
    obs.disable()
    counts = {}
    for s in obs.spans():
        counts[s.cat] = counts.get(s.cat, 0) + 1
    print(f"traced {sum(counts.values())} spans {counts} -> {trace_path}")
    print(rep)
    print(f"unified counters: { {ns: len(v) for ns, v in obs.snapshot().items()} }")
    obs.clear()

    # -- serving: many tenants, one engine (DESIGN.md §9) ------------------
    # StencilEngine batches same-fingerprint requests into ONE vmapped
    # dispatch over a slot pool; results stay bitwise-equal to the solo
    # time_loop above.  frame_every streams intermediate states.
    from repro.serve.stencil import StencilEngine

    eng = StencilEngine()
    handles = [
        eng.submit(prog, (jnp.asarray(u0),), n_steps=4 * k, target=target,
                   frame_every=2 * k, tenant=f"tenant{i}")
        for i in range(3)
    ]
    eng.run()
    served = np.asarray(handles[0].result()[0])
    solo = np.asarray(step.time_loop([jnp.asarray(u0)], 4 * k)[0])
    snap = eng.metrics.snapshot()
    print(f"served 3 tenants in {snap['engine_steps']} engine steps "
          f"({snap['batched_dispatches']} batched dispatches, "
          f"{snap['frames_emitted']} frames); "
          f"bitwise-equal to solo: {np.array_equal(served, solo)}")

    # -- elastic pools: an ensemble burst (DESIGN.md §9) -------------------
    # an ensemble study lands as a same-instant burst of one fingerprint:
    # the queue-depth autoscaler grows the slot pool to meet it, shrinks
    # it on the long tail (resizes ride the checkpoint-migration path, so
    # results stay bitwise), and the drained bucket retires — its pooled
    # device arrays freed.
    from repro.serve.stencil import (
        PoolSizerConfig, StencilEngine as _Eng, StencilEngineConfig,
    )

    burst_eng = _Eng(StencilEngineConfig(
        slots_per_group=2,
        autoscale=PoolSizerConfig(min_capacity=1, max_capacity=8,
                                  cooldown_steps=1, ewma_alpha=1.0),
        bucket_idle_steps=4,
    ))
    rng = np.random.default_rng(0)
    members = [u0 + 0.01 * rng.standard_normal(grid.shape).astype(np.float32)
               for _ in range(8)]
    # most members run short; the last runs long, so after the burst
    # drains the pool sits underutilized and the autoscaler shrinks it
    member_steps = [4 * k] * 7 + [24 * k]
    burst_handles = [
        burst_eng.submit(prog, (jnp.asarray(m),), n_steps=n,
                         target=target, tenant=f"member{i}")
        for i, (m, n) in enumerate(zip(members, member_steps))
    ]
    burst_eng.run()
    for _ in range(5):  # idle steps: let the drained bucket retire
        burst_eng.step()
    auto = burst_eng.metrics.snapshot()["autoscale"]
    print(f"ensemble burst of {len(burst_handles)}: pool grew "
          f"{auto['grows']}x / shrank {auto['shrinks']}x, "
          f"{burst_eng.metrics.buckets_retired} bucket retired after drain")

    # crude ASCII rendering of the diffused blob
    ds = uT[:: args.size // 32, :: args.size // 32]
    chars = " .:-=+*#%@"
    for row in ds:
        print("".join(chars[int(min(v, 0.999) * 10)] for v in row))


if __name__ == "__main__":
    main()
